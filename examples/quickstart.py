"""Quickstart: one schedule clause for every scheduling decision.

Runs on CPU in seconds:
    PYTHONPATH=src python examples/quickstart.py
"""

import os

import numpy as np

from repro.core import (LoopSpec, ScheduleSpec, plan_schedule, resolve,
                        simulate_loop)
from repro.core import lambda_style as ls
from repro.sched import pack_with_scheduler

# --- 1. a custom UDS in the declare style (paper §4.2), by name -------------
# examples/uds_blocks.py declares the paper's Fig. 2 "mystatic" as
# "blocks" with a make_args factory; importing it registers the name in
# the unified ScheduleSpec registry (CLIs load it via REPRO_UDS_MODULES).
import uds_blocks  # noqa: F401  (registration side effect)

res = simulate_loop(resolve("uds:blocks"),
                    LoopSpec(0, 100, num_workers=4, chunk=8),
                    np.ones(100))
print(f"declare-style 'uds:blocks': makespan={res.makespan:.1f}, "
      f"dequeues={res.dequeues}")


# --- 2. the same idea in the lambda style (paper §4.1), as a template -------
def tmpl_init():
    ls.OMP_UDS_user_ptr()["next"] = ls.OMP_UDS_loop_start()


def tmpl_dequeue():
    ptr = ls.OMP_UDS_user_ptr()
    if ptr["next"] >= ls.OMP_UDS_loop_end():
        return 0
    c = ls.OMP_UDS_chunksize()
    ls.OMP_UDS_loop_chunk_start(ptr["next"])
    ls.OMP_UDS_loop_chunk_end(min(ptr["next"] + c, ls.OMP_UDS_loop_end()))
    ptr["next"] += c
    return 1


if "lblocks" not in ls.registered_templates():
    ls.schedule_template("lblocks", init=tmpl_init, dequeue=tmpl_dequeue,
                         uds_data={"next": 0})

# "uds:lblocks,8" mirrors schedule(UDS:8, template(lblocks))
res = simulate_loop(resolve("uds:lblocks,8"),
                    LoopSpec(0, 100, num_workers=4), np.ones(100))
print(f"lambda-style template 'uds:lblocks,8': makespan={res.makespan:.1f}")


# --- 3. one clause grammar for the whole literature library -----------------
rng = np.random.default_rng(0)
costs = rng.lognormal(0.0, 1.5, 2000)          # heavy-tailed iterations
print("\nschedule clause    makespan  (P=8, lognormal costs, overhead=1e-4)")
for clause in ("static", "dynamic,1", "guided,4", "tss", "fac2",
               "taper(mu=1.0,sigma=1.5)", "awf_b", "af", "uds:lblocks,16"):
    r = simulate_loop(resolve(clause),
                      LoopSpec(0, 2000, num_workers=8, loop_id=clause),
                      costs, overhead=1e-4)
    print(f"  {clause:18s} {r.makespan:8.2f}")

# schedule(runtime): the clause is late-bound from the environment
os.environ.setdefault("REPRO_SCHEDULE", "guided,4")
sched = resolve("runtime")
print(f"\nschedule(runtime) with REPRO_SCHEDULE={os.environ['REPRO_SCHEDULE']!r}"
      f" resolved to {sched._spec}")

# equal specs share one cached plan in the engine, however they were built
assert ScheduleSpec.make("guided", chunk=4) == ScheduleSpec.make("guided,4")

# the same clause drives any substrate — here, document packing
docs = [rng.integers(1, 50, size=int(n)).astype(np.int32)
        for n in rng.integers(8, 400, 64)]
packed = pack_with_scheduler("uds:lblocks,4", docs, 8, 1024)
print(f"packing under 'uds:lblocks,4': fill={packed.fill_fraction:.3f}")


# --- 4. UDS chunk tables feeding a Pallas kernel -----------------------------
import jax.numpy as jnp
from repro.kernels.sched_matmul.ops import scheduled_matmul, tile_order_from_plan

plan = plan_schedule(resolve("tss"), 8, 2)     # 8 M-tiles, 2 workers
order = tile_order_from_plan(plan, 8)
a = jnp.asarray(rng.normal(size=(8 * 128, 64)), jnp.float32)
b = jnp.asarray(rng.normal(size=(64, 128)), jnp.float32)
out = scheduled_matmul(a, b, jnp.asarray(order), block_k=64, interpret=True)
err = float(jnp.abs(out - a @ b).max())
print(f"\nsched_matmul with TSS tile order {order.tolist()}: max|err|={err:.2e}")
