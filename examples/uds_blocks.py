"""A paper-Fig.-2 user-defined schedule, registered for by-name use.

The declare-style ``mystatic`` of the paper (static chunking written by
the user, state in a loop record passed as ``omp_arg0``), declared with a
``make_args`` factory so the unified ScheduleSpec registry can conjure a
fresh loop record whenever the schedule is selected *by name* — e.g. from
a CLI entry point::

    REPRO_UDS_MODULES=examples.uds_blocks \
        python -m repro.launch.train --arch qwen2.5-3b --smoke --steps 2 \
        --scheduler "uds:blocks,8"

(``REPRO_UDS_MODULES`` is the late registration point: comma-separated
modules imported before the first registry lookup.)
"""

from repro.core import declare


class LoopRecord:
    """The user's loop record (the paper's ``uds_data`` / ``&lr``)."""

    next = 0
    ub = 0
    chunk = 1


def my_init(lb, ub, inc, chunk, nw, lr):
    lr.next = lb
    lr.ub, lr.chunk = ub, max(chunk, 1)


def my_next(lower, upper, step, lr):
    if lr.next >= lr.ub:
        return 0                      # the paper's "return 0"
    lower.set(lr.next)
    upper.set(min(lr.next + lr.chunk, lr.ub))
    lr.next = upper.value
    return 1


if "blocks" not in declare.registered_schedules():
    declare.declare_schedule(
        "blocks", arguments=1,
        init=declare.call(my_init, declare.OMP_LB, declare.OMP_UB,
                          declare.OMP_INCR, declare.OMP_CHUNKSZ,
                          declare.OMP_NUM_WORKERS, declare.ARG(0)),
        next=declare.call(my_next, declare.OMP_LB_CHUNK,
                          declare.OMP_UB_CHUNK, declare.OMP_CHUNK_INCR,
                          declare.ARG(0)),
        make_args=lambda: (LoopRecord(),))
